"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles,
executed in interpret mode (kernel body runs on CPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.acceptor import acceptor_phase2_window
from repro.kernels.coordinator import coordinator_sequence_window
from repro.kernels.digest import digest as kdigest, tree_digest
from repro.kernels.learner import learner_quorum_window

RNG = np.random.default_rng(0)


def _assert_tree_equal(a, b):
    for x, y in zip(a, b, strict=True):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# acceptor
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "n,b,v", [(512, 128, 8), (256, 256, 16), (1024, 64, 4), (128, 128, 1), (2048, 512, 32)]
)
def test_acceptor_sweep(n, b, v):
    bb = min(128, b)
    st_rnd = jnp.asarray(RNG.integers(0, 3, n).astype(np.int32))
    st_vrnd = jnp.asarray(RNG.integers(-1, 2, n).astype(np.int32))
    st_val = jnp.asarray(RNG.integers(-99, 99, (n, v)).astype(np.int32))
    base = int(RNG.integers(0, n // bb)) * bb
    mt = jnp.asarray(RNG.choice([3, 0, 1], size=b).astype(np.int32))
    mr = jnp.asarray(RNG.integers(0, 4, b).astype(np.int32))
    mv = jnp.asarray(RNG.integers(-9, 9, (b, v)).astype(np.int32))
    k = acceptor_phase2_window(
        st_rnd, st_vrnd, st_val, base, 2, mt, mr, mv, interpret=True
    )
    r = ref.acceptor_phase2_window(st_rnd, st_vrnd, st_val, base, 2, mt, mr, mv)
    _assert_tree_equal(k, r)


def test_acceptor_ring_wraparound():
    n, b, v = 256, 128, 4
    st = (
        jnp.zeros((n,), jnp.int32),
        jnp.full((n,), -1, jnp.int32),
        jnp.zeros((n, v), jnp.int32),
    )
    mt = jnp.full((b,), 3, jnp.int32)
    mr = jnp.zeros((b,), jnp.int32)
    mv = jnp.arange(b * v, dtype=jnp.int32).reshape(b, v)
    # base at the last block: window [128, 256) -> no wrap; then instance
    # 256..384 maps back to slots [0, 128)
    out1 = acceptor_phase2_window(*st, n - b, 0, mt, mr, mv, interpret=True)
    out2 = acceptor_phase2_window(out1[0], out1[1], out1[2], n, 0, mt, mr, mv,
                                  interpret=True)
    np.testing.assert_array_equal(np.asarray(out2[2][:b]), np.asarray(mv))
    r1 = ref.acceptor_phase2_window(*st, n - b, 0, mt, mr, mv)
    r2 = ref.acceptor_phase2_window(r1[0], r1[1], r1[2], n, 0, mt, mr, mv)
    _assert_tree_equal(out2, r2)


def test_acceptor_state_is_protocol_memory():
    """Re-proposing a lower round to a voted slot must be rejected."""
    n, b, v = 128, 128, 2
    st = (
        jnp.full((n,), 5, jnp.int32),
        jnp.full((n,), 5, jnp.int32),
        jnp.ones((n, v), jnp.int32) * 7,
    )
    mt = jnp.full((b,), 3, jnp.int32)
    mr = jnp.full((b,), 3, jnp.int32)  # lower than promised 5
    mv = jnp.zeros((b, v), jnp.int32)
    out = acceptor_phase2_window(*st, 0, 0, mt, mr, mv, interpret=True)
    assert (np.asarray(out[3]) == 7).all()          # all REJECT
    np.testing.assert_array_equal(np.asarray(out[2]), np.asarray(st[2]))  # unchanged


# ---------------------------------------------------------------------------
# coordinator
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b", [64, 128, 256, 512])
@pytest.mark.parametrize("next_inst", [0, 123, 65536])
def test_coordinator_sweep(b, next_inst):
    active = jnp.asarray(RNG.integers(0, 2, b).astype(np.int32))
    k = coordinator_sequence_window(
        jnp.int32(next_inst), jnp.int32(9), active, interpret=True
    )
    r = ref.coordinator_sequence_window(jnp.int32(next_inst), jnp.int32(9), active)
    _assert_tree_equal(k, r)
    # monotonic contiguity — the invariant the acceptor kernel relies on
    inst = np.asarray(k[1])
    assert (np.diff(inst) == 1).all()
    assert inst[0] == next_inst


# ---------------------------------------------------------------------------
# learner
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("a,b,v", [(3, 128, 8), (5, 128, 4), (3, 256, 16), (7, 128, 2)])
def test_learner_sweep(a, b, v):
    quorum = a // 2 + 1
    vt = jnp.asarray(RNG.choice([4, 7, 0], size=(a, b)).astype(np.int32))
    vr = jnp.asarray(RNG.integers(0, 3, (a, b)).astype(np.int32))
    vv = jnp.asarray(RNG.integers(-5, 5, (a, b, v)).astype(np.int32))
    k = learner_quorum_window(jnp.int32(quorum), vt, vr, vv, interpret=True)
    r = ref.learner_quorum_window(quorum, vt, vr, vv)
    _assert_tree_equal(k, r)


def test_learner_quorum_boundary():
    """Exactly f votes -> no deliver; f+1 -> deliver."""
    a, b, v = 3, 128, 2
    vr = jnp.zeros((a, b), jnp.int32)
    vv = jnp.ones((a, b, v), jnp.int32)
    # one vote only
    vt1 = jnp.asarray(np.array([[4] * b, [7] * b, [7] * b], np.int32))
    d1 = learner_quorum_window(jnp.int32(2), vt1, vr, vv, interpret=True)[0]
    assert not np.asarray(d1).any()
    vt2 = jnp.asarray(np.array([[4] * b, [4] * b, [7] * b], np.int32))
    d2 = learner_quorum_window(jnp.int32(2), vt2, vr, vv, interpret=True)[0]
    assert np.asarray(d2).all()


# ---------------------------------------------------------------------------
# digest
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [128, 1000, 32768, 100_000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32, jnp.bfloat16])
def test_digest_sweep(n, dtype):
    if dtype == jnp.bfloat16:
        x = jnp.asarray(RNG.standard_normal(n), jnp.float32).astype(jnp.bfloat16)
        x = x.astype(jnp.float32)  # digest folds f32 bit patterns
    elif dtype == jnp.int32:
        x = jnp.asarray(RNG.integers(-9, 9, n).astype(np.int32))
    else:
        x = jnp.asarray(RNG.standard_normal(n).astype(np.float32))
    block = 32 * 1024
    pad = (-n) % min(block, n)
    want = ref.digest(jnp.pad(x.reshape(-1), (0, pad)))
    got = kdigest(x, interpret=True)
    assert int(got) == int(want)


def test_digest_order_sensitive():
    x = jnp.asarray(RNG.standard_normal(256).astype(np.float32))
    y = x[::-1]
    assert int(kdigest(x, interpret=True)) != int(kdigest(y, interpret=True))


def test_tree_digest_detects_divergence():
    t1 = {"a": jnp.ones((64,), jnp.float32), "b": jnp.zeros((32,), jnp.float32)}
    t2 = {"a": jnp.ones((64,), jnp.float32), "b": jnp.zeros((32,), jnp.float32).at[7].set(1e-7)}
    assert int(tree_digest(t1, interpret=True)) != int(tree_digest(t2, interpret=True))
    assert int(tree_digest(t1, interpret=True)) == int(tree_digest(jax.tree_util.tree_map(lambda x: x + 0.0, t1), interpret=True))
