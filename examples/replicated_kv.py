"""Replicated key-value store — the paper's LevelDB case study (§5).

    PYTHONPATH=src python examples/replicated_kv.py

Three replicas each hold an independent store; clients submit serialized
get/put/delete ops through the unchanged submit/deliver API; CAANS makes the
replicas consistent.  "No code from LevelDB needed to be modified" — here the
store is a dict behind the same boundary.
"""
import sys

sys.path.insert(0, "src")

from repro.core import PaxosConfig, PaxosContext, ReplicatedLog


class Replica:
    """A storage server: applies the decided log in order."""

    def __init__(self, rid: int):
        self.rid = rid
        self.store = {}
        self.log = ReplicatedLog(quorum=2)
        self.log.on_apply = self._apply

    def _apply(self, inst: int, op: bytes) -> None:
        kind, _, rest = op.partition(b":")
        if kind == b"put":
            k, _, v = rest.partition(b"=")
            self.store[k.decode()] = v.decode()
        elif kind == b"del":
            self.store.pop(rest.decode(), None)

    def offer(self, inst: int, op: bytes) -> None:
        self.log.offer(inst, op)


def main() -> None:
    replicas = [Replica(i) for i in range(3)]

    def deliver(value, size, inst):
        for r in replicas:
            r.offer(inst, bytes(value))

    ctx = PaxosContext(
        PaxosConfig(n_acceptors=3, n_instances=4096, batch=16),
        deliver=deliver,
        fused=True,
    )

    ops = [
        b"put:user=alice",
        b"put:city=lugano",
        b"put:user=bob",       # overwrite decided later than the first put
        b"del:city",
        b"put:paper=caans",
    ]
    for op in ops:
        ctx.submit(op)
    ctx.run_until_quiescent()

    expect = {"user": "bob", "paper": "caans"}
    for r in replicas:
        assert r.store == expect, (r.rid, r.store)
        assert r.log.apply_watermark == len(ops)
    print(f"3 replicas consistent after {len(ops)} ops: {replicas[0].store}")

    # checkpoint + trim (paper §3.1 memory-limitation protocol): f+1 learners
    # ack the watermark, acceptor log below it becomes reclaimable
    wm = replicas[0].log.apply_watermark
    replicas[0].log.ack_trim(0, wm)
    replicas[0].log.ack_trim(1, wm)
    assert replicas[0].log.trim_watermark == wm
    print(f"log trimmed to instance {wm} after quorum checkpoint ack")


if __name__ == "__main__":
    main()
