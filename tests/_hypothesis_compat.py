"""Optional-hypothesis shim for mixed test modules.

``from _hypothesis_compat import given, settings, st`` gives the real
hypothesis decorators when the package is installed, and skip-marking stubs
otherwise — so deterministic tests in the same module keep running in
runtime-only environments (the full dev deps live in requirements-dev.txt).
Modules whose tests are *all* property-based should instead use
``pytest.importorskip("hypothesis")``.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies``; strategy expressions in
        decorator arguments evaluate to inert placeholders."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()
